"""Compact-resident patchy state (ProjSpec.compact): scatter-free learn
parity against the dense-compute reference, structural no-dense-leaf
guarantees, index-table persistence/memoization, checkpoint migration
round-trip, and serving integration."""
import dataclasses
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compact as compact_mod
from repro.core.bcpnn_layer import (
    ProjSpec,
    _learn_jnp,
    forward,
    init_projection,
    learn,
    rewire,
    validate_patchy_state,
)
from repro.core.compact import densify_pij
from repro.core.hypercolumns import LayerGeom

COMPACT = ProjSpec(LayerGeom(13, 2), LayerGeom(5, 10), alpha=0.2, nact=4,
                   backend="jnp", patchy_traces=True, compact=True)


def _steps(spec, n, seed=1, b=19):
    for k in jax.random.split(jax.random.PRNGKey(seed), n):
        kx, ky = jax.random.split(k)
        yield (jax.random.uniform(kx, (b, spec.pre.N)),
               jax.random.uniform(ky, (b, spec.post.N)))


def _dense_view(proj, spec):
    return np.asarray(densify_pij(proj.traces.pij, proj.traces.pi,
                                  proj.traces.pj, proj.table, spec.pre.M))


# ----------------------------------------------------- spec validation ----

def test_compact_spec_requires_patchy_budget():
    with pytest.raises(ValueError, match="compact"):
        ProjSpec(LayerGeom(8, 2), LayerGeom(4, 8), compact=True)
    with pytest.raises(ValueError, match="compact"):
        ProjSpec(LayerGeom(8, 2), LayerGeom(4, 8), nact=3, compact=True)


# ------------------------------------------------ structural guarantees ----

def test_compact_state_carries_no_dense_leaf():
    """The acceptance invariant: a compact projection's pytree has NO
    (Ni, Nj)-shaped leaf — pij and w are (Hj, K, Mj), plus the (Hj, nact)
    table; nothing on the learn path can touch dense storage."""
    proj = init_projection(COMPACT, jax.random.PRNGKey(0))
    ni, nj = COMPACT.pre.N, COMPACT.post.N
    k = COMPACT.nact * COMPACT.pre.M
    want = (COMPACT.post.H, k, COMPACT.post.M)
    assert proj.traces.pij.shape == want
    assert proj.w.shape == want
    assert proj.table.shape == (COMPACT.post.H, COMPACT.nact)
    for leaf in jax.tree.leaves(proj):
        assert tuple(leaf.shape) != (ni, nj), \
            f"dense (Ni, Nj) leaf leaked into compact state: {leaf.shape}"
    # ... and learning preserves the invariant
    x, y = next(_steps(COMPACT, 1))
    for spec in (COMPACT, COMPACT.with_backend("pallas")):
        out = learn(proj, spec, x, y)
        for leaf in jax.tree.leaves(out):
            assert tuple(leaf.shape) != (ni, nj)


# ------------------------------------- learn: parity with the reference ----

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_compact_learn_matches_dense_reference_including_rewire(backend):
    """Scatter-free compact learn vs the dense-compute reference of the
    same semantics (_learn_jnp on a dense-layout state with a compact
    spec) — through 8 chained steps with a rewire in the middle.  The
    independence-product definition of silent synapses makes the rewire
    MI ranking identical on both sides, so masks and tables stay in
    lockstep across the event."""
    spec = dataclasses.replace(COMPACT, backend=backend)
    ref_init = dataclasses.replace(COMPACT, compact=False)
    proj_ref = init_projection(ref_init, jax.random.PRNGKey(0))
    proj_c = init_projection(COMPACT, jax.random.PRNGKey(0))
    proj_c = jax.tree.map(jnp.array, proj_c)
    for i, (x, y) in enumerate(_steps(COMPACT, 8)):
        proj_ref = _learn_jnp(proj_ref, COMPACT, x, y)
        proj_c = learn(proj_c, spec, x, y)
        np.testing.assert_allclose(_dense_view(proj_c, spec),
                                   np.asarray(proj_ref.traces.pij),
                                   atol=1e-6, err_msg=f"pij at step {i}")
        np.testing.assert_allclose(np.asarray(proj_c.b),
                                   np.asarray(proj_ref.b), atol=1e-6)
        if i == 3:
            proj_ref = rewire(proj_ref, COMPACT)
            proj_c = rewire(proj_c, spec)
            np.testing.assert_array_equal(np.asarray(proj_ref.mask),
                                          np.asarray(proj_c.mask))
            assert np.all(np.asarray(proj_c.mask).sum(0) == COMPACT.nact)
        xf = jax.random.uniform(jax.random.PRNGKey(100 + i),
                                (7, spec.pre.N))
        np.testing.assert_allclose(
            np.asarray(forward(proj_c, spec, xf)),
            np.asarray(forward(proj_ref, ref_init.with_backend("jnp"), xf)),
            atol=1e-5, err_msg=f"forward at step {i}")


def test_compact_active_entries_match_dense_patchy_schedule():
    """While the mask is static, active joint-trace entries follow the
    same EMA recursion as the dense-resident patchy path, so weights and
    forward outputs agree; only silent entries differ (held vs
    independence)."""
    spec_held = dataclasses.replace(COMPACT, compact=False,
                                    backend="pallas")
    spec_c = COMPACT.with_backend("pallas")
    proj_h = init_projection(spec_held, jax.random.PRNGKey(0))
    proj_c = init_projection(COMPACT, jax.random.PRNGKey(0))
    for x, y in _steps(COMPACT, 5):
        proj_h = learn(proj_h, spec_held, x, y)
        proj_c = learn(proj_c, spec_c, x, y)
    from repro.core.compact import gather_dense, unit_indices
    ui = unit_indices(proj_c.table, COMPACT.pre.M, sentinel=COMPACT.pre.N)
    held_active = gather_dense(proj_h.traces.pij, ui, COMPACT.post.H,
                               COMPACT.post.M)
    np.testing.assert_allclose(np.asarray(proj_c.traces.pij),
                               np.asarray(held_active), atol=1e-6)
    x = jax.random.uniform(jax.random.PRNGKey(9), (11, COMPACT.pre.N))
    np.testing.assert_allclose(np.asarray(forward(proj_c, spec_c, x)),
                               np.asarray(forward(proj_h, spec_held, x)),
                               atol=1e-5)


# ------------------------------------------- index-table persistence ----

def test_table_not_rebuilt_between_consecutive_learn_steps(monkeypatch):
    """Regression for the per-call top_k: on compact state the (Hj, nact)
    table is a state leaf, so consecutive learn/forward steps must not
    invoke the table builder at all."""
    proj = init_projection(COMPACT, jax.random.PRNGKey(0))
    calls = []
    real = compact_mod.build_table

    def spy(mask, nact):
        calls.append(1)
        return real(mask, nact)

    monkeypatch.setattr(compact_mod, "build_table", spy)
    (x1, y1), (x2, y2) = list(_steps(COMPACT, 2))
    for spec in (COMPACT, COMPACT.with_backend("pallas")):
        p = learn(proj, spec, x1, y1)
        p = learn(p, spec, x2, y2)
        forward(p, spec, x1)
    assert calls == [], f"table rebuilt {len(calls)}x on the compact hot path"


def test_dense_resident_table_memoized_on_mask_identity(monkeypatch):
    """The dense-resident patchy path derives its table from the mask —
    memoized two-level (DESIGN.md §8): identity fast path, then content
    digest, so repeated eager kernel calls AND fold-boundary copies of
    the same mask do one top_k; only a rewire (new mask CONTENT)
    invalidates."""
    from repro.kernels import fused_forward

    # The memo is module-global and content-keyed: other tests using the
    # same geometry/seed would pre-populate it — start from empty.
    compact_mod._TABLE_CACHE.clear()
    compact_mod._TABLE_CONTENT_CACHE.clear()
    spec = dataclasses.replace(COMPACT, compact=False, backend="pallas")
    proj = init_projection(spec, jax.random.PRNGKey(0))
    calls = []
    real = compact_mod.build_table

    def spy(mask, nact):
        calls.append(1)
        return real(mask, nact)

    monkeypatch.setattr(compact_mod, "build_table", spy)
    x = jax.random.uniform(jax.random.PRNGKey(1), (9, spec.pre.N))
    fused_forward(proj, spec, x)
    n_first = len(calls)
    assert n_first >= 1
    fused_forward(proj, spec, x)
    fused_forward(proj, spec, x)
    assert len(calls) == n_first, "same mask object was re-derived"
    # A NEW buffer with the SAME content (what a fold boundary produces)
    # must hit the content-digest level — no rebuild.
    proj2 = dataclasses.replace(proj, mask=jnp.array(proj.mask))
    fused_forward(proj2, spec, x)
    assert len(calls) == n_first, "same-content mask copy must hit the memo"
    # Changed CONTENT (a rewire) must rebuild.
    moved = init_projection(spec, jax.random.PRNGKey(7)).mask
    assert not bool(jnp.array_equal(moved, proj.mask))
    proj3 = dataclasses.replace(proj, mask=moved)
    fused_forward(proj3, spec, x)
    assert len(calls) > n_first, "new mask content must invalidate the memo"


# ----------------------------------------------- checkpoint migration ----

def _load_migrate():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                        "migrate_ckpt.py")
    mod_spec = importlib.util.spec_from_file_location("migrate_ckpt", path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return mod


def test_migrate_ckpt_roundtrip_bit_identical_inference(tmp_path):
    """Dense checkpoint -> migrate CLI -> compact checkpoint: the migrated
    manifest restores on its own spec and serves bit-identical inference
    (the forward kernels see the same gathered operands either way)."""
    from repro.checkpoint import CheckpointManager
    from repro.core.network import (init_deep, infer, make_network_spec,
                                    spec_from_dict)
    from repro.core.trainer import Trainer

    spec = make_network_spec(LayerGeom(16, 2), [(4, 8)], n_classes=3,
                             alpha=1e-2, nact=[5], backend="pallas",
                             patchy_traces=True)
    tr = Trainer(spec, seed=0)
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (64, 32)))
    y = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 3))
    tr.fit(x, y, epochs=1, batch=16)
    src = str(tmp_path / "dense")
    dst = str(tmp_path / "compact")
    tr.save(src)

    migrate = _load_migrate()
    assert migrate.main(["--ckpt", src, "--out", dst]) == 0

    mgr = CheckpointManager(dst)
    step = mgr.latest_step()
    new_spec = spec_from_dict(mgr.read_extra(step)["spec"])
    assert new_spec.projs[0].compact
    state = mgr.restore(step, init_deep(new_spec, jax.random.PRNGKey(0)))
    validate_patchy_state(state.projs[0], new_spec.projs[0])
    assert state.projs[0].traces.pij.ndim == 3

    xe = jnp.asarray(x[:32])
    probs_d, pred_d = infer(tr.state, spec, xe)
    probs_c, pred_c = infer(state, new_spec, xe)
    np.testing.assert_array_equal(np.asarray(probs_c), np.asarray(probs_d))
    np.testing.assert_array_equal(np.asarray(pred_c), np.asarray(pred_d))

    # restoring the migrated manifest into a DENSE target fails loudly
    # with the layout-mismatch hint, not a generic structure error
    with pytest.raises(ValueError, match="migrate_ckpt"):
        mgr.restore(step, init_deep(spec, jax.random.PRNGKey(0)))


def test_migrate_ckpt_refuses_non_patchy_checkpoint(tmp_path):
    from repro.core.network import make_network_spec
    from repro.core.trainer import Trainer

    spec = make_network_spec(LayerGeom(8, 2), [(2, 4)], n_classes=2)
    tr = Trainer(spec, seed=0)
    src = str(tmp_path / "dense")
    tr.save(src)
    migrate = _load_migrate()
    assert migrate.main(["--ckpt", src, "--out",
                         str(tmp_path / "out")]) == 2


# ----------------------------------------------- serving integration ----

def test_serving_engine_compact_infer_and_online_learning():
    """A compact-resident network serves through BCPNNService — the
    bucketed infer path dispatches to the scatter-free kernels and
    matches the jnp reference network — and online learning folds
    feedback into the readout with the compact stack frozen."""
    from repro.core.network import infer as net_infer
    from repro.core.network import init_deep, make_network_spec
    from repro.serve import BCPNNService

    spec = make_network_spec(LayerGeom(16, 2), [(4, 8)], n_classes=3,
                             alpha=1e-2, nact=[5], backend="pallas",
                             patchy_traces=True, compact=True)
    state = init_deep(spec, jax.random.PRNGKey(0))
    xs = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (12, 32)))
    want = np.asarray(net_infer(state, spec.with_backend("jnp"),
                                jnp.asarray(xs))[1])
    svc = BCPNNService(state, spec, max_batch=8, max_wait_ms=2.0,
                      online_learning=True, feedback_batch=4).start()
    try:
        ids = [svc.submit(x) for x in xs]
        got = np.asarray([svc.result(i).pred for i in ids])
        for x in xs[:6]:
            svc.feedback(x, 1)
    finally:
        svc.stop()
    np.testing.assert_array_equal(got, want)
    assert svc.metrics.snapshot()["learn_samples"] >= 6
    assert svc.state.projs[0].traces.pij.ndim == 3  # stack stayed compact


def test_serving_engine_rejects_drifted_table():
    """A compact state whose index table disagrees with its mask must be
    refused at the deployment boundary."""
    from repro.core.network import init_deep, make_network_spec
    from repro.serve import BCPNNService

    spec = make_network_spec(LayerGeom(10, 2), [(4, 8)], n_classes=3,
                             nact=[3], backend="pallas",
                             patchy_traces=True, compact=True)
    state = init_deep(spec, jax.random.PRNGKey(0))
    bad_table = jnp.roll(state.projs[0].table, 1, axis=0)
    bad = dataclasses.replace(
        state,
        projs=(dataclasses.replace(state.projs[0], table=bad_table),))
    with pytest.raises(ValueError, match="disagrees with the mask"):
        BCPNNService(bad, spec, max_batch=8)


def test_serving_engine_rejects_duplicate_table_entries():
    """A table row with a DUPLICATED pre-HC index (paired with an
    under-full mask column, so the scattered mask could spuriously
    match) must also be refused — it would gather the same pre block
    twice."""
    from repro.core.network import init_deep, make_network_spec
    from repro.serve import BCPNNService

    spec = make_network_spec(LayerGeom(10, 2), [(4, 8)], n_classes=3,
                             nact=[3], backend="pallas",
                             patchy_traces=True, compact=True)
    state = init_deep(spec, jax.random.PRNGKey(0))
    table = np.asarray(state.projs[0].table).copy()
    mask = np.asarray(state.projs[0].mask).copy()
    mask[table[0, 1], 0] = 0.0       # drop one live pre-HC from column 0
    table[0, 1] = table[0, 0]        # duplicate another in its place
    bad = dataclasses.replace(
        state,
        projs=(dataclasses.replace(state.projs[0],
                                   table=jnp.asarray(table),
                                   mask=jnp.asarray(mask)),))
    with pytest.raises(ValueError, match="disagrees with the mask"):
        BCPNNService(bad, spec, max_batch=8)
