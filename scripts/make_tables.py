"""Emit the EXPERIMENTS.md roofline tables from dry-run artifacts."""
import glob
import json
import sys


def load(d):
    return sorted((json.load(open(f)) for f in glob.glob(f"{d}/*.json")),
                  key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                 r.get("variant", "")))


def fmt(d, mesh=None, variants=False):
    rows = []
    for r in load(d):
        if mesh and r["mesh"] != mesh:
            continue
        if not variants and r.get("variant"):
            continue
        tag = f"| {r['arch']} | {r['shape']} | {r['mesh']}"
        if variants:
            tag += f" | {r.get('variant') or '-'}"
        if r["status"] == "skipped":
            rows.append(f"{tag} | skipped | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"{tag} | FAILED | — | — | — | — | — |")
            continue
        rf = r["roofline"]
        peak = r.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30
        rows.append(
            f"{tag} | {rf['bottleneck']} | {rf['compute_s']*1e3:.1f} | "
            f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} | "
            f"{rf['useful_ratio']:.2f} | {peak:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_v2"
    mesh = sys.argv[2] if len(sys.argv) > 2 else None
    variants = len(sys.argv) > 3
    print(fmt(which, mesh or None, variants))
