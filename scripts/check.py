"""Repo invariant check — the one-command gate CI and pre-push hooks run.

    python scripts/check.py            # lint + contracts, strict
    python scripts/check.py --no-contracts   # lint only (fast)

Thin wrapper over ``python -m repro.analysis --strict --contracts`` that
works from any CWD without PYTHONPATH plumbing.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main(argv: "list[str] | None" = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    fast = "--no-contracts" in argv
    argv = [a for a in argv if a != "--no-contracts"]
    # Must precede any jax import: the DP-seam check needs a 2-device
    # host mesh, and platform flags are read once at jax init.
    from repro.analysis.contracts import ensure_host_devices
    ensure_host_devices(2)
    os.environ.setdefault("JAX_PLATFORMS",
                          os.environ.get("JAX_PLATFORM_NAME", "") or "cpu")
    from repro.analysis.__main__ import main as analysis_main
    args = ["--strict"] + ([] if fast else ["--contracts"]) + argv
    return analysis_main(args)


if __name__ == "__main__":
    sys.exit(main())
