"""One-shot dense -> compact-resident checkpoint migration.

Rewrites a checkpoint whose patchy-trace projections store dense
(Ni, Nj) joint traces/weights into the compact-resident (Hj, K, Mj)
layout (ProjSpec.compact, DESIGN.md §7), updating the spec stored in the
manifest so servers and trainers rebuild the compact network from the
migrated directory alone.  Inference over the migrated state is
bit-identical: the compact forward kernels see exactly the operands the
dense-resident patchy path gathered per call.  Silent synapses' stale
held trace values are dropped — under the compact semantics they are the
independence product, which is also what a post-migration ``rewire``
ranks them as (0 MI).

    PYTHONPATH=src python scripts/migrate_ckpt.py \
        --ckpt runs/model1 --out runs/model1_compact [--step N]

The source checkpoint must carry its NetworkSpec in the manifest
(``Trainer.save`` does this); pre-spec checkpoints cannot be migrated
blind — retrain or re-save them first.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _leaf_bytes(tree) -> int:
    import jax
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--ckpt", required=True,
                    help="source checkpoint directory (CheckpointManager "
                         "layout, spec in the manifest)")
    ap.add_argument("--out", required=True,
                    help="destination directory for the migrated checkpoint")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step to migrate (default: latest)")
    args = ap.parse_args(argv)

    import os
    if not os.path.isdir(args.ckpt):
        print(f"migrate_ckpt: checkpoint directory {args.ckpt!r} does not "
              f"exist", file=sys.stderr)
        return 2

    import jax
    from repro.checkpoint import CheckpointManager
    from repro.core.bcpnn_layer import validate_patchy_state
    from repro.core.compact import compactify_state
    from repro.core.network import init_deep, spec_from_dict, spec_to_dict

    src = CheckpointManager(args.ckpt)
    step = args.step if args.step is not None else src.latest_step()
    if step is None:
        print(f"migrate_ckpt: no checkpoints under {args.ckpt}",
              file=sys.stderr)
        return 2
    extra = src.read_extra(step) or {}
    if "spec" not in extra:
        print(f"migrate_ckpt: step_{step} has no NetworkSpec in its "
              f"manifest (extra['spec']); re-save it with Trainer.save "
              f"before migrating", file=sys.stderr)
        return 2
    spec = spec_from_dict(extra["spec"])
    eligible = [i for i, p in enumerate(spec.projs)
                if p.patchy_traces and not p.compact
                and p.nact is not None and p.nact < p.pre.H]
    if not eligible:
        print("migrate_ckpt: no dense-resident patchy-trace projections to "
              "migrate (need patchy_traces=True, a binding nact, and "
              "compact=False)", file=sys.stderr)
        return 2

    state = src.restore(step, init_deep(spec, jax.random.PRNGKey(0)))
    before = _leaf_bytes(state)
    new_state, new_spec = compactify_state(state, spec)
    for l in eligible:
        validate_patchy_state(new_state.projs[l], new_spec.projs[l],
                              where=f"migrated stack proj {l}")
    after = _leaf_bytes(new_state)

    dst = CheckpointManager(args.out)
    dst.save(step, new_state, blocking=True,
             extra={**extra, "spec": spec_to_dict(new_spec)})
    for l in eligible:
        p = new_spec.projs[l]
        print(f"migrate_ckpt: proj {l}: (Ni={p.pre.N}, Nj={p.post.N}) dense "
              f"-> (Hj={p.post.H}, K={p.nact * p.pre.M}, Mj={p.post.M}) "
              f"compact")
    print(f"migrate_ckpt: step_{step} {args.ckpt} -> {args.out}; state "
          f"bytes {before} -> {after} "
          f"({100.0 * (before - after) / max(1, before):.1f}% smaller)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
